"""AdamW with decoupled weight decay + global-norm clipping + LR schedules.

Optimizer state mirrors the param pytree, so whatever sharding the params
carry, the moments inherit it (ZeRO-equivalent given fully sharded params).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray        # () int32
    mu: PyTree               # first moment
    nu: PyTree               # second moment


def adamw_init(params: PyTree, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-8))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(grads: PyTree, state: AdamWState, params: PyTree, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    """One AdamW step. `lr` may be a scalar or a schedule value."""
    step = state.step + 1
    b1t = 1.0 - b1 ** step.astype(jnp.float32)
    b2t = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * gf
        v = b2 * v + (1.0 - b2) * jnp.square(gf)
        mhat = m / b1t
        vhat = v / b2t
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return m, v, (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    mu = treedef.unflatten([o[0] for o in out])
    nu = treedef.unflatten([o[1] for o in out])
    new_params = treedef.unflatten([o[2] for o in out])
    return new_params, AdamWState(step=step, mu=mu, nu=nu)


def cosine_warmup_schedule(step, *, peak_lr: float, warmup_steps: int,
                           total_steps: int, min_ratio: float = 0.1):
    """Linear warmup then cosine decay to min_ratio * peak."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / max(warmup_steps, 1)
    prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                    0.0, 1.0)
    cos = min_ratio + (1.0 - min_ratio) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return peak_lr * jnp.where(step < warmup_steps, warm, cos)
