"""Claim: acceleration factor ~= T/m (survey §III-B complexity analysis).

The survey derives O(m*C1 + (T-m)*C2) total cost when m of T steps compute
fully and cache retrieval C2 << C1, i.e. speedup ~ T/m = 1/compute_fraction.
We measure wall-clock per trajectory on a ~5M-param DiT for FORA at several
intervals and compare with the predicted T/m line.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import make_policy, compute_fraction
from repro.diffusion import linear_schedule, sample, ddim_step
from repro.diffusion.pipeline import CachedDenoiser

from .common import save_result, small_dit, timeit

NUM_STEPS = 40


def run():
    cfg, params = small_dit()
    sched = linear_schedule(1000)
    ts = sched.spaced(NUM_STEPS)
    key = jax.random.PRNGKey(0)
    xT = jax.random.normal(key, (2, cfg.dit_patch_tokens, cfg.dit_in_dim))

    rows = []
    base_t = None
    for interval in (1, 2, 4, 8):
        policy = make_policy("fora", interval=interval)
        den = CachedDenoiser(params, cfg, policy, granularity="model")

        def traj():
            x0, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                           denoiser_state=den.init_state(2))
            return x0

        jtraj = jax.jit(traj)
        t = timeit(jtraj, reps=3)
        frac = compute_fraction(policy.static_schedule(NUM_STEPS))
        if interval == 1:
            base_t = t
        rows.append({
            "interval": interval,
            "compute_fraction": frac,
            "predicted_speedup": 1.0 / frac,
            "wall_s": t,
            "measured_speedup": base_t / t,
        })
        print(f"N={interval}: frac={frac:.3f} predicted={1/frac:.2f}x "
              f"measured={base_t/t:.2f}x ({t*1e3:.0f} ms)")

    save_result("bench_speedup", {"num_steps": NUM_STEPS, "rows": rows})
    return rows


if __name__ == "__main__":
    run()
