"""Roofline table assembler: reads benchmarks/results/dryrun_*.json (emitted
by repro.launch.dryrun) and renders the §Roofline table for EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from .common import RESULTS_DIR, save_result


def run():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun_*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh"), "status": "skipped",
                         "reason": rec.get("skip_reason")})
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec.get("mesh"), "status": "error",
                         "reason": rec.get("error", "?")[:120]})
            continue
        rl = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "status": "ok",
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "bytes_per_device_gb": rec["bytes_per_device"] / 1e9,
            "fits": rec["fits_16gb_hbm"],
            "useful_flops_ratio": rec.get("useful_flops_ratio"),
        })

    ok = [r for r in rows if r["status"] == "ok"]
    print(f"{'arch':18s} {'shape':12s} {'mesh':10s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
          f"{'dom':>10s} {'GB/dev':>7s} fit")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:18s} {r['shape']:12s} -- {r['status']}: "
                  f"{r.get('reason','')}")
            continue
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:10s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>10s} "
              f"{r['bytes_per_device_gb']:7.1f} {r['fits']}")
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\n{len(ok)} ok; dominant terms: {doms}")
    save_result("roofline_table", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
