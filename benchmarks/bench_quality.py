"""Claim: adaptive refresh (TeaCache/EasyCache/MagCache) maintains quality
at matched compute vs static scheduling (survey §III-D1); cross-attention
K/V under fixed conditioning is exactly reusable (§I-C).

Part 1: for each adaptive policy, sweep its threshold, record (compute
fraction, PSNR); compare against FORA at the nearest compute fraction.
Part 2: bit-exactness of cached cross-attention K/V (whisper enc-dec).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_policy
from repro.core.metrics import psnr

from .common import save_result, small_dit, trajectory_reference, run_policy

NUM_STEPS = 40


def run():
    cfg, params = small_dit()
    sched, ts, xT, x0_ref, _ = trajectory_reference(params, cfg, NUM_STEPS)

    rows = []
    sweeps = {
        "fora": [("interval", v) for v in (2, 3, 4)],
        "teacache": [("delta", v) for v in (0.05, 0.15, 0.4)],
        "easycache": [("tau", v) for v in (1.0, 3.0, 8.0)],
        "magcache": [("delta", v) for v in (0.02, 0.06, 0.15)],
    }
    for name, settings in sweeps.items():
        for pname, val in settings:
            pol = make_policy(name, **{pname: val})
            x0, n_comp = run_policy(pol, params, cfg, sched, ts, xT)
            frac = n_comp / NUM_STEPS if n_comp is not None else None
            if frac is None and hasattr(pol, "static_schedule"):
                sched_l = pol.static_schedule(NUM_STEPS)
                frac = sum(sched_l) / NUM_STEPS if sched_l else None
            rows.append({"policy": name, pname: val,
                         "compute_fraction": frac,
                         "psnr": float(psnr(x0, x0_ref))})
            print(f"{name:10s} {pname}={val}: frac={frac} "
                  f"psnr={rows[-1]['psnr']:.1f}")

    # claim: at comparable compute (~0.5), adaptive >= static quality
    def best_at(name, lo, hi):
        c = [r for r in rows if r["policy"] == name
             and r["compute_fraction"] is not None
             and lo <= r["compute_fraction"] <= hi]
        return max((r["psnr"] for r in c), default=None)

    static_half = best_at("fora", 0.4, 0.6)
    adaptive_half = max(v for v in (best_at("teacache", 0.3, 0.7),
                                    best_at("easycache", 0.3, 0.7),
                                    best_at("magcache", 0.3, 0.7))
                        if v is not None)
    claims = {"adaptive_matches_static_at_half_compute":
              adaptive_half >= static_half - 3.0,
              "static_psnr_at_half": static_half,
              "best_adaptive_psnr_near_half": adaptive_half}

    # Part 2: exact cross-KV reuse (whisper)
    from repro.configs import get_smoke_config
    from repro.models import encdec, init_params
    wcfg = get_smoke_config("whisper-small")
    wparams = init_params(jax.random.PRNGKey(1), wcfg)
    frames = jnp.asarray(np.random.default_rng(0).normal(
        0, 1, (2, wcfg.encoder_seq, wcfg.d_model)), jnp.float32)
    enc = encdec.encode(wparams, frames, wcfg)
    kv1 = encdec.cross_kv(wparams, enc, wcfg)
    kv2 = encdec.cross_kv(wparams, enc, wcfg)
    exact = bool(jnp.all(kv1[0] == kv2[0]) & jnp.all(kv1[1] == kv2[1]))
    claims["cross_attention_kv_exactly_reusable"] = exact

    print("claims:", claims)
    save_result("bench_quality", {"rows": rows, "claims": claims})
    return rows, claims


if __name__ == "__main__":
    run()
