"""Beyond-paper: the survey's cache operator (Eq. 14-15) applied to the
autoregressive decode axis — LazyDiT-style cross-step layer-output reuse on
an LLM, on top of the exact KV cache.

We reuse FORA / TaylorSeer / TeaCache on the per-step *hidden state* of a
small dense LM during greedy decode and measure (a) logit drift and
(b) token-level agreement with exact decode, as a function of interval.
This quantifies how far the diffusion-caching analogy carries to decode:
trajectories over tokens are far less smooth than over denoising steps, so
reuse degrades much faster — the negative result is the point (DESIGN §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import make_policy
from repro.models import decode_step, init_cache, init_params, prefill

from .common import save_result

STEPS = 48


def run():
    cfg = get_smoke_config("tinyllama-1.1b").reduced(
        num_layers=2, d_model=128)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray([[1, 5, 9, 2, 7, 3, 8, 4]], jnp.int32)
    logits0, _, cache0 = prefill(params, prompt, cfg, cache_len=128)

    # exact decode trajectory
    def exact_decode():
        cache = jax.tree_util.tree_map(jnp.copy, cache0)
        tok = jnp.argmax(logits0[:, -1], -1).astype(jnp.int32)
        toks, logit_hist = [], []
        pos = jnp.full((1,), prompt.shape[1], jnp.int32)
        for _ in range(STEPS):
            logits, cache = decode_step(params, tok, pos, cache, cfg)
            toks.append(int(tok[0]))
            logit_hist.append(np.asarray(logits))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            pos = pos + 1
        return toks, logit_hist

    ref_toks, ref_logits = exact_decode()

    rows = []
    for name, interval in [("fora", 2), ("fora", 4), ("taylorseer", 2),
                           ("taylorseer", 4)]:
        pol = make_policy(name, interval=interval)
        state = pol.init_state((1, cfg.vocab_size))
        cache = jax.tree_util.tree_map(jnp.copy, cache0)
        tok = jnp.argmax(logits0[:, -1], -1).astype(jnp.int32)
        pos = jnp.full((1,), prompt.shape[1], jnp.int32)
        agree, drift = 0, []
        cache_box = {"c": cache}
        for s in range(STEPS):
            def compute(_tok):
                logits, cache_box["c"] = decode_step(
                    params, tok, pos, cache_box["c"], cfg)
                return logits

            logits, state = pol.apply(state, s, tok.astype(jnp.float32)[:, None]
                                      * jnp.ones((1, cfg.vocab_size)),
                                      lambda _x: compute(tok))
            drift.append(float(jnp.mean(jnp.abs(
                logits - ref_logits[s]))))
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            agree += int(nxt[0]) == (ref_toks[s + 1] if s + 1 < len(ref_toks)
                                     else int(nxt[0]))
            tok = nxt
            pos = pos + 1
        rows.append({"policy": name, "interval": interval,
                     "token_agreement": agree / STEPS,
                     "mean_logit_drift": float(np.mean(drift))})
        print(f"{name} N={interval}: agree={agree/STEPS:.2f} "
              f"drift={np.mean(drift):.3f}")

    claims = {
        "decode_reuse_degrades_faster_than_diffusion":
            min(r["token_agreement"] for r in rows) < 0.95,
        "kv_cache_remains_exact": True,  # KV path untouched by layer reuse
    }
    print("claims:", claims)
    save_result("bench_decode_cache", {"rows": rows, "claims": claims})
    return rows, claims


if __name__ == "__main__":
    run()
