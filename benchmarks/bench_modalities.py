"""Multi-modal caching: cached vs uncached per modality + mixed-pool serving.

The survey's subtitle — *Toward Efficient Multi-Modal Generation* — makes
two claims this benchmark measures end-to-end on the modality layer
(repro.modalities):

  1. Cross-modality trajectory sweep: the same cache operator accelerates
     image, video and audio DiTs alike (SmoothCache's demonstration).  For
     each modality we run the exact trajectory and a cached one and report
     compute fraction + PSNR; the video workload additionally runs the two
     temporal-aware schemes — TeaCache-temporal (per-frame signal
     reduction) and the PAB branch broadcast (temporal attention reused
     over a longer range than spatial).
  2. Mixed-modality serving: one image + video + audio pool under the
     MixedModalityEngine umbrella.  The structural claim (checked in smoke
     mode too): temporal caching reduces the backbone rows dispatched on
     the video workload vs the uncached baseline on the SAME queue, while
     the cached engine's output stays equal to its own single-trajectory
     reference (the fidelity invariant) — quality vs the uncached baseline
     is reported as PSNR alongside.

T2I mode (`--mode t2i`): the text-conditioned serving claim.  The same
prompted queue is served twice through a t2i engine + PromptCache — cold
(every prompt unique: the encoder runs once per request) and hot (a small
popular-prompt set: the encoder runs once per POPULAR prompt, everything
else is a host-side cache hit).  Reports hot/cold req/s, prompt-cache
hit rates, and the serving redundancy ratio; structural assertions
(encoder-call counts, zero steady-state recompiles under the retrace
sentinel, fidelity vs the single-trajectory prompted reference) hold in
smoke mode too.

`--smoke` (CI) shrinks models/queues so the whole run takes seconds;
timing-dependent assertions are skipped there, structural ones kept.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import save_result


def _workloads(smoke: bool):
    from repro.configs import get_config
    from repro.modalities import get_modality, make_workload

    sizes = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                 d_ff=128, dit_in_dim=4, dit_num_classes=10) if smoke else \
        dict(num_layers=4, d_model=192, num_heads=4, num_kv_heads=4,
             d_ff=768, dit_in_dim=8, dit_num_classes=10)
    out = {}
    for name in ("image", "video", "audio"):
        spec = get_modality(name)
        overrides = dict(sizes)
        if spec.temporal:
            overrides.update(dit_patch_tokens=8 if smoke else 16,
                             dit_num_frames=2 if smoke else 4)
        else:
            overrides.update(dit_patch_tokens=16 if smoke else 64)
        cfg = get_config(spec.arch_id).reduced(**overrides)
        out[name] = make_workload(name, cfg=cfg)
    return out


#: per-modality cached policies for the trajectory sweep — the temporal
#: entries only make sense on the video workload
TRAJECTORY_POLICIES = {
    "image": [("fora", {"interval": 4}), ("teacache", {"delta": 0.1})],
    "video": [("fora", {"interval": 4}),
              ("teacache_video", {"delta": 0.1})],
    "audio": [("fora", {"interval": 4}), ("taylorseer", {"interval": 4})],
}


def run_trajectories(workloads, *, num_steps, smoke):
    from repro.core.metrics import psnr
    from repro.diffusion import ddim_step, linear_schedule, sample

    print(f"{'modality':8s} {'policy':16s} {'cf':>6s} {'psnr':>8s}")
    rows, failures = [], []
    sched = linear_schedule(1000)
    ts = sched.spaced(num_steps)
    for name, wl in workloads.items():
        xT = wl.noise(jax.random.PRNGKey(0), 2)
        den0 = wl.denoiser()
        exact, _ = sample(den0, xT, ts, sched, step_fn=ddim_step,
                          denoiser_state=den0.init_state(2))
        exact = np.asarray(exact)
        for pol_name, kw in TRAJECTORY_POLICIES[name]:
            pol = wl.make_policy(pol_name, num_steps=num_steps, **kw)
            den = wl.denoiser(pol)
            x0, state = sample(den, xT, ts, sched, step_fn=ddim_step,
                               denoiser_state=den.init_state(2))
            pst = state["policy"]
            n_comp = (int(pst["n_compute"]) if isinstance(pst, dict)
                      and "n_compute" in pst else
                      sum(map(bool, pol.static_schedule(num_steps) or
                              [True] * num_steps)))
            cf = n_comp / num_steps
            q = float(psnr(np.asarray(x0), exact))
            rows.append({"modality": name, "policy": pol_name,
                         "compute_fraction": cf, "psnr_db": q})
            print(f"{name:8s} {pol_name:16s} {cf:6.3f} {q:8.2f}")
            if not cf < 1.0:
                failures.append(f"{pol_name} on {name} never skipped")
            if not np.isfinite(x0).all():
                failures.append(f"{pol_name} on {name} non-finite output")

        if name == "video":
            # PAB branch broadcast: per-module-type ranges, temporal
            # attention reused longest (repro.core.temporal)
            den = wl.denoiser(granularity="pab_video")
            x0, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                           denoiser_state=den.init_state(2))
            cf = wl.pab_stack().compute_fraction(num_steps)
            q = float(psnr(np.asarray(x0), exact))
            rows.append({"modality": name, "policy": "pab_video",
                         "compute_fraction": cf, "psnr_db": q})
            print(f"{name:8s} {'pab_video':16s} {cf:6.3f} {q:8.2f}")
            if not cf < 1.0:
                failures.append("pab_video broadcast never reused a branch")
    return rows, failures


def run_mixed_serving(workloads, *, num_steps, num_requests, slots, smoke):
    from repro.core import make_policy
    from repro.core.metrics import psnr
    from repro.diffusion import ddim_step, linear_schedule, sample
    from repro.modalities import MixedModalityEngine
    from repro.serving.diffusion import DiffusionRequest, request_noise_key

    mods = ("image", "video", "audio")
    reqs = [DiffusionRequest(i, num_steps=num_steps, seed=i,
                             class_label=i % 5, modality=mods[i % 3])
            for i in range(num_requests)]

    def build(mode: str):
        if mode == "temporal":
            # the modality-aware mix: signal policies where the signal
            # matters (per-frame on video), interval policy on audio
            pools = {
                "image": workloads["image"].engine(
                    make_policy("teacache", delta=0.1), slots=slots,
                    max_steps=num_steps),
                "video": workloads["video"].engine(
                    workloads["video"].make_policy(
                        "teacache_video", delta=0.1, num_steps=num_steps),
                    slots=slots, max_steps=num_steps),
                "audio": workloads["audio"].engine(
                    make_policy("fora", interval=4), slots=slots,
                    max_steps=num_steps),
            }
        elif mode == "static":
            # interval-scheduled everywhere: the whole pool plans ticks on
            # the host (no want-compute round trips), so this is where the
            # serving-level THROUGHPUT claim lives — state-dependent
            # policies pay a per-tick device round trip + per-slot signal
            # pass that tiny models don't amortize (same caveat as
            # bench_serving's unguided sweep)
            pools = {m: workloads[m].engine(
                make_policy("fora", interval=4), slots=slots,
                max_steps=num_steps) for m in mods}
        else:
            pools = {m: workloads[m].engine("none", slots=slots,
                                            max_steps=num_steps)
                     for m in mods}
        return MixedModalityEngine(pools)

    print(f"\n-- mixed image+video+audio pool ({slots} slots/modality, "
          f"{num_requests} requests) --")
    print(f"{'engine':9s} {'req/s':>8s} {'rows':>7s} {'tokens':>8s} "
          f"{'video rows':>11s}")
    out, results = {}, {}
    for mode in ("temporal", "static", "none"):
        eng = build(mode)
        eng.warmup()   # pre-compile every sub-pool's bucket programs
        res = eng.serve(reqs)
        assert len(res) == num_requests
        assert all(np.isfinite(r.x0).all() for r in res)
        s = eng.telemetry.summary()
        out[mode], results[mode] = s, res
        print(f"{mode:9s} {s['throughput_rps']:8.2f} "
              f"{s['backbone_rows_computed']:7d} "
              f"{s['backbone_tokens_computed']:8d} "
              f"{s['rows_by_modality']['video']:11d}")

    failures = []
    # acceptance: temporal caching cuts the video pool's backbone rows on
    # the same queue vs the uncached baseline
    v_cached = out["temporal"]["rows_by_modality"]["video"]
    v_none = out["none"]["rows_by_modality"]["video"]
    print(f"video backbone rows: {v_cached} temporal vs {v_none} uncached "
          f"({v_none / max(v_cached, 1):.2f}x fewer)")
    if not v_cached < v_none:
        failures.append(f"temporal caching did not cut video backbone rows: "
                        f"{v_cached} vs {v_none}")
    if not (out["temporal"]["backbone_rows_computed"] <
            out["none"]["backbone_rows_computed"]):
        failures.append("mixed pool: caching did not cut total rows")

    # fidelity invariant: every cached video request equals its own
    # single-trajectory reference (serving introduces no extra error)...
    wl = workloads["video"]
    sched = linear_schedule(1000)
    ts = sched.spaced(num_steps)
    video_reqs = [(r, res) for r, res in zip(reqs, results["temporal"])
                  if r.modality == "video"][:2]
    for req, res in video_reqs:
        xT = jax.random.normal(request_noise_key(req),
                               (1, wl.tokens, wl.latent_dim))
        den = wl.denoiser(wl.make_policy("teacache_video", delta=0.1,
                                         num_steps=num_steps),
                          class_label=req.class_label)
        ref, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                        denoiser_state=den.init_state(1))
        if not np.allclose(res.x0, np.asarray(ref[0]), atol=5e-3, rtol=1e-3):
            failures.append(f"video request {req.request_id}: served output "
                            f"diverged from its cached reference")
            break
    # ...and quality vs the uncached baseline is reported as PSNR
    qs = [float(psnr(a.x0, b.x0))
          for a, b in zip(results["temporal"], results["none"])
          if a.record.modality == "video"]
    q_video = sum(qs) / max(len(qs), 1)
    print(f"video temporal-vs-uncached PSNR: {q_video:.2f} dB")
    if not smoke and q_video < 10.0:
        failures.append(f"video cached output collapsed: {q_video:.2f} dB")

    # serving-level throughput claim on the host-plannable pool
    ratio = (out["static"]["throughput_rps"] / out["none"]["throughput_rps"])
    ratio_t = (out["temporal"]["throughput_rps"] /
               out["none"]["throughput_rps"])
    print(f"static-vs-none mixed-pool throughput: {ratio:.2f}x "
          f"(temporal pool: {ratio_t:.2f}x — pays per-tick want-compute "
          f"round trips that small models don't amortize)")
    if not smoke and ratio <= 1.0:
        failures.append(f"mixed-pool interval caching did not beat none: "
                        f"{ratio:.2f}x")
    return {"throughput_ratio_static": ratio,
            "throughput_ratio_temporal": ratio_t,
            "video_rows": {"temporal": v_cached, "none": v_none},
            "video_psnr_db": q_video,
            "summaries": out}, failures


def run_t2i(*, smoke: bool):
    """Prompted t2i serving, hot vs cold prompt traffic.

    Cold: every request carries a unique prompt — the text encoder runs
    once per request.  Hot: requests draw from a small popular-prompt set
    — the encoder runs once per POPULAR prompt and every other admission
    is a host-side PromptCache hit.  The tick programs are identical in
    both runs (text K/V are per-slot operands), so the req/s gap isolates
    what prompt-level caching is worth at admission time."""
    from repro.analysis.ir import RetraceSentinel
    from repro.configs import get_config
    from repro.core import FasterCacheCFG, make_policy
    from repro.diffusion import ddim_step, linear_schedule, sample
    from repro.modalities import get_modality, make_workload
    from repro.obs import redundancy_ratio
    from repro.serving.diffusion import DiffusionRequest, request_noise_key

    spec = get_modality("t2i")
    sizes = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                 d_ff=128, dit_in_dim=4, dit_num_classes=10,
                 dit_patch_tokens=16, dit_text_len=8) if smoke else \
        dict(num_layers=4, d_model=192, num_heads=4, num_kv_heads=4,
             d_ff=768, dit_in_dim=8, dit_num_classes=10,
             dit_patch_tokens=64, dit_text_len=16)
    wl = make_workload("t2i", cfg=get_config(spec.arch_id).reduced(**sizes))

    num_steps = 8 if smoke else 16
    slots = 2 if smoke else 4
    n = 6 if smoke else 24
    popular = ("a photo of a cat", "a watercolor fox")

    def queue(kind):
        # cold prompts differ inside the first dit_text_len tokens, so
        # truncation can't fold them into one cache entry
        prompts = ([popular[i % len(popular)] for i in range(n)]
                   if kind == "hot"
                   else [f"{i}: a one-off prompt" for i in range(n)])
        return [DiffusionRequest(
            i, num_steps=num_steps, seed=i, class_label=i % 3,
            cfg_scale=2.0 if i % 2 == 0 else 0.0, prompt_tokens=prompts[i],
            neg_prompt_tokens="blurry" if i % 2 == 0 else None)
            for i in range(n)]

    print(f"\n-- t2i prompted serving ({slots} slots, {n} requests, "
          f"text_len={wl.cfg.dit_text_len}) --")
    print(f"{'traffic':8s} {'req/s':>8s} {'enc runs':>9s} {'hit rate':>9s} "
          f"{'redund':>7s}")
    out, results, failures = {}, {}, []
    for kind in ("hot", "cold"):
        cond = wl.conditioner(seed=0)
        # a signal policy: per-slot firing diverges, so row compaction
        # (and with it the redundancy ratio) has something to save
        eng = wl.engine(make_policy("teacache", delta=0.1), slots=slots,
                        max_steps=num_steps,
                        cfg_policy=FasterCacheCFG(4, num_steps),
                        conditioner=cond)
        profiles = eng.warmup()
        # unmeasured warm serve (bench_serving idiom): host paths and the
        # allocator settle before the measured run
        eng.serve([DiffusionRequest(10_000 + i, num_steps=num_steps,
                                    seed=i, cfg_scale=2.0,
                                    prompt_tokens="warm serve prompt")
                   for i in range(slots)])
        warm = dict(cond.stats)      # measured deltas exclude the warm serve
        with RetraceSentinel() as sentinel:
            res = eng.serve(queue(kind))
        assert len(res) == n
        if not all(np.isfinite(r.x0).all() for r in res):
            failures.append(f"t2i {kind}: non-finite output")
        if sentinel.count:
            failures.append(f"t2i {kind}: {sentinel.count} steady-state "
                            f"recompile(s): {sentinel.compiled_names}")
        s = eng.telemetry.summary()
        red = redundancy_ratio(profiles, s["backbone_rows_computed"],
                               s["backbone_rows_padding"],
                               s["backbone_rows_saved"])
        misses = cond.misses - warm["misses"]
        hits = cond.hits - warm["hits"]
        pc = {"misses": misses, "hits": hits,
              "hit_rate": hits / max(hits + misses, 1)}
        # best-of-two req/s: the first measured serve in a process carries
        # allocator/OS noise that would drown the admission-time signal
        rps = max(s["throughput_rps"],
                  (eng.serve(queue(kind)),
                   eng.telemetry.summary()["throughput_rps"])[1])
        out[kind] = {"throughput_rps": rps,
                     "prompt_cache": pc,
                     "redundancy_ratio": red["redundancy_ratio"],
                     "recompiles": sentinel.count}
        results[kind] = res
        print(f"{kind:8s} {rps:8.2f} "
              f"{pc['misses']:9d} {pc['hit_rate']:9.2f} "
              f"{red['redundancy_ratio']:7.3f}")

    # encoder-call accounting IS the prompt-cache claim: once per popular
    # prompt (+1 for the shared negative) hot, once per request cold
    hot, cold = out["hot"]["prompt_cache"], out["cold"]["prompt_cache"]
    if hot["misses"] != len(popular) + 1:
        failures.append(f"t2i hot traffic ran the encoder {hot['misses']} "
                        f"times, expected {len(popular) + 1}")
    if cold["misses"] != n + 1:
        failures.append(f"t2i cold traffic ran the encoder "
                        f"{cold['misses']} times, expected {n + 1}")
    if not hot["hit_rate"] > cold["hit_rate"]:
        failures.append("t2i popular-prompt traffic did not out-hit cold")

    # fidelity invariant: a served prompted+guided request equals its own
    # single-trajectory CachedDenoiser(text=, neg_text=) reference
    cond = wl.conditioner(seed=0)
    req = queue("hot")[0]
    sched = linear_schedule(1000)
    ts = sched.spaced(num_steps)
    xT = jax.random.normal(request_noise_key(req),
                           (1, wl.tokens, wl.latent_dim))
    den = wl.denoiser(make_policy("teacache", delta=0.1),
                      cfg_scale=req.cfg_scale,
                      cfg_policy=FasterCacheCFG(4, num_steps),
                      text=cond.get(req.prompt_tokens),
                      neg_text=cond.get(req.neg_prompt_tokens))
    ref, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                    denoiser_state=den.init_state(1))
    if not np.allclose(results["hot"][0].x0, np.asarray(ref[0]), atol=5e-3,
                       rtol=1e-3):
        failures.append("t2i served output diverged from its prompted "
                        "single-trajectory reference")

    ratio = (out["hot"]["throughput_rps"] /
             max(out["cold"]["throughput_rps"], 1e-9))
    print(f"hot-vs-cold prompt traffic: {ratio:.2f}x req/s "
          f"(encoder runs {hot['misses']} vs {cold['misses']})")
    return {"hot_vs_cold_rps": ratio, "traffic": out}, failures


def run(smoke: bool = False, json_out: bool = False, mode: str = "all"):
    if mode == "t2i":
        t2i, fails = run_t2i(smoke=smoke)
        payload = {"t2i": t2i, "smoke": smoke, "failures": fails}
        save_result("modalities_t2i", payload)
        if json_out:
            save_result("BENCH_modalities_t2i", payload)
        if fails:
            raise AssertionError("; ".join(fails))
        return
    workloads = _workloads(smoke)
    if smoke:
        traj_rows, fails = run_trajectories(workloads, num_steps=8,
                                            smoke=True)
        mixed, mfails = run_mixed_serving(workloads, num_steps=8,
                                          num_requests=6, slots=2,
                                          smoke=True)
    else:
        traj_rows, fails = run_trajectories(workloads, num_steps=24,
                                            smoke=False)
        mixed, mfails = run_mixed_serving(workloads, num_steps=16,
                                          num_requests=12, slots=4,
                                          smoke=False)
    payload = {"trajectories": traj_rows, "mixed": mixed,
               "smoke": smoke, "failures": fails + mfails}
    save_result("modalities", payload)
    if json_out:
        save_result("BENCH_modalities", payload)
    if fails or mfails:
        raise AssertionError("; ".join(fails + mfails))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + few ticks (CI per-PR run)")
    ap.add_argument("--json", action="store_true",
                    help="also write results/BENCH_modalities.json (the "
                         "stable-name copy CI uploads as an artifact)")
    ap.add_argument("--mode", choices=("all", "t2i"), default="all",
                    help="'t2i' runs just the prompted hot-vs-cold serving "
                         "comparison (the CI smoke job runs both modes)")
    args = ap.parse_args()
    run(smoke=args.smoke, json_out=args.json, mode=args.mode)
