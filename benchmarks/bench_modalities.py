"""Multi-modal caching: cached vs uncached per modality + mixed-pool serving.

The survey's subtitle — *Toward Efficient Multi-Modal Generation* — makes
two claims this benchmark measures end-to-end on the modality layer
(repro.modalities):

  1. Cross-modality trajectory sweep: the same cache operator accelerates
     image, video and audio DiTs alike (SmoothCache's demonstration).  For
     each modality we run the exact trajectory and a cached one and report
     compute fraction + PSNR; the video workload additionally runs the two
     temporal-aware schemes — TeaCache-temporal (per-frame signal
     reduction) and the PAB branch broadcast (temporal attention reused
     over a longer range than spatial).
  2. Mixed-modality serving: one image + video + audio pool under the
     MixedModalityEngine umbrella.  The structural claim (checked in smoke
     mode too): temporal caching reduces the backbone rows dispatched on
     the video workload vs the uncached baseline on the SAME queue, while
     the cached engine's output stays equal to its own single-trajectory
     reference (the fidelity invariant) — quality vs the uncached baseline
     is reported as PSNR alongside.

`--smoke` (CI) shrinks models/queues so the whole run takes seconds;
timing-dependent assertions are skipped there, structural ones kept.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import save_result


def _workloads(smoke: bool):
    from repro.configs import get_config
    from repro.modalities import get_modality, make_workload

    sizes = dict(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                 d_ff=128, dit_in_dim=4, dit_num_classes=10) if smoke else \
        dict(num_layers=4, d_model=192, num_heads=4, num_kv_heads=4,
             d_ff=768, dit_in_dim=8, dit_num_classes=10)
    out = {}
    for name in ("image", "video", "audio"):
        spec = get_modality(name)
        overrides = dict(sizes)
        if spec.temporal:
            overrides.update(dit_patch_tokens=8 if smoke else 16,
                             dit_num_frames=2 if smoke else 4)
        else:
            overrides.update(dit_patch_tokens=16 if smoke else 64)
        cfg = get_config(spec.arch_id).reduced(**overrides)
        out[name] = make_workload(name, cfg=cfg)
    return out


#: per-modality cached policies for the trajectory sweep — the temporal
#: entries only make sense on the video workload
TRAJECTORY_POLICIES = {
    "image": [("fora", {"interval": 4}), ("teacache", {"delta": 0.1})],
    "video": [("fora", {"interval": 4}),
              ("teacache_video", {"delta": 0.1})],
    "audio": [("fora", {"interval": 4}), ("taylorseer", {"interval": 4})],
}


def run_trajectories(workloads, *, num_steps, smoke):
    from repro.core.metrics import psnr
    from repro.diffusion import ddim_step, linear_schedule, sample

    print(f"{'modality':8s} {'policy':16s} {'cf':>6s} {'psnr':>8s}")
    rows, failures = [], []
    sched = linear_schedule(1000)
    ts = sched.spaced(num_steps)
    for name, wl in workloads.items():
        xT = wl.noise(jax.random.PRNGKey(0), 2)
        den0 = wl.denoiser()
        exact, _ = sample(den0, xT, ts, sched, step_fn=ddim_step,
                          denoiser_state=den0.init_state(2))
        exact = np.asarray(exact)
        for pol_name, kw in TRAJECTORY_POLICIES[name]:
            pol = wl.make_policy(pol_name, num_steps=num_steps, **kw)
            den = wl.denoiser(pol)
            x0, state = sample(den, xT, ts, sched, step_fn=ddim_step,
                               denoiser_state=den.init_state(2))
            pst = state["policy"]
            n_comp = (int(pst["n_compute"]) if isinstance(pst, dict)
                      and "n_compute" in pst else
                      sum(map(bool, pol.static_schedule(num_steps) or
                              [True] * num_steps)))
            cf = n_comp / num_steps
            q = float(psnr(np.asarray(x0), exact))
            rows.append({"modality": name, "policy": pol_name,
                         "compute_fraction": cf, "psnr_db": q})
            print(f"{name:8s} {pol_name:16s} {cf:6.3f} {q:8.2f}")
            if not cf < 1.0:
                failures.append(f"{pol_name} on {name} never skipped")
            if not np.isfinite(x0).all():
                failures.append(f"{pol_name} on {name} non-finite output")

        if name == "video":
            # PAB branch broadcast: per-module-type ranges, temporal
            # attention reused longest (repro.core.temporal)
            den = wl.denoiser(granularity="pab_video")
            x0, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                           denoiser_state=den.init_state(2))
            cf = wl.pab_stack().compute_fraction(num_steps)
            q = float(psnr(np.asarray(x0), exact))
            rows.append({"modality": name, "policy": "pab_video",
                         "compute_fraction": cf, "psnr_db": q})
            print(f"{name:8s} {'pab_video':16s} {cf:6.3f} {q:8.2f}")
            if not cf < 1.0:
                failures.append("pab_video broadcast never reused a branch")
    return rows, failures


def run_mixed_serving(workloads, *, num_steps, num_requests, slots, smoke):
    from repro.core import make_policy
    from repro.core.metrics import psnr
    from repro.diffusion import ddim_step, linear_schedule, sample
    from repro.modalities import MixedModalityEngine
    from repro.serving.diffusion import DiffusionRequest, request_noise_key

    mods = ("image", "video", "audio")
    reqs = [DiffusionRequest(i, num_steps=num_steps, seed=i,
                             class_label=i % 5, modality=mods[i % 3])
            for i in range(num_requests)]

    def build(mode: str):
        if mode == "temporal":
            # the modality-aware mix: signal policies where the signal
            # matters (per-frame on video), interval policy on audio
            pools = {
                "image": workloads["image"].engine(
                    make_policy("teacache", delta=0.1), slots=slots,
                    max_steps=num_steps),
                "video": workloads["video"].engine(
                    workloads["video"].make_policy(
                        "teacache_video", delta=0.1, num_steps=num_steps),
                    slots=slots, max_steps=num_steps),
                "audio": workloads["audio"].engine(
                    make_policy("fora", interval=4), slots=slots,
                    max_steps=num_steps),
            }
        elif mode == "static":
            # interval-scheduled everywhere: the whole pool plans ticks on
            # the host (no want-compute round trips), so this is where the
            # serving-level THROUGHPUT claim lives — state-dependent
            # policies pay a per-tick device round trip + per-slot signal
            # pass that tiny models don't amortize (same caveat as
            # bench_serving's unguided sweep)
            pools = {m: workloads[m].engine(
                make_policy("fora", interval=4), slots=slots,
                max_steps=num_steps) for m in mods}
        else:
            pools = {m: workloads[m].engine("none", slots=slots,
                                            max_steps=num_steps)
                     for m in mods}
        return MixedModalityEngine(pools)

    print(f"\n-- mixed image+video+audio pool ({slots} slots/modality, "
          f"{num_requests} requests) --")
    print(f"{'engine':9s} {'req/s':>8s} {'rows':>7s} {'tokens':>8s} "
          f"{'video rows':>11s}")
    out, results = {}, {}
    for mode in ("temporal", "static", "none"):
        eng = build(mode)
        eng.warmup()   # pre-compile every sub-pool's bucket programs
        res = eng.serve(reqs)
        assert len(res) == num_requests
        assert all(np.isfinite(r.x0).all() for r in res)
        s = eng.telemetry.summary()
        out[mode], results[mode] = s, res
        print(f"{mode:9s} {s['throughput_rps']:8.2f} "
              f"{s['backbone_rows_computed']:7d} "
              f"{s['backbone_tokens_computed']:8d} "
              f"{s['rows_by_modality']['video']:11d}")

    failures = []
    # acceptance: temporal caching cuts the video pool's backbone rows on
    # the same queue vs the uncached baseline
    v_cached = out["temporal"]["rows_by_modality"]["video"]
    v_none = out["none"]["rows_by_modality"]["video"]
    print(f"video backbone rows: {v_cached} temporal vs {v_none} uncached "
          f"({v_none / max(v_cached, 1):.2f}x fewer)")
    if not v_cached < v_none:
        failures.append(f"temporal caching did not cut video backbone rows: "
                        f"{v_cached} vs {v_none}")
    if not (out["temporal"]["backbone_rows_computed"] <
            out["none"]["backbone_rows_computed"]):
        failures.append("mixed pool: caching did not cut total rows")

    # fidelity invariant: every cached video request equals its own
    # single-trajectory reference (serving introduces no extra error)...
    wl = workloads["video"]
    sched = linear_schedule(1000)
    ts = sched.spaced(num_steps)
    video_reqs = [(r, res) for r, res in zip(reqs, results["temporal"])
                  if r.modality == "video"][:2]
    for req, res in video_reqs:
        xT = jax.random.normal(request_noise_key(req),
                               (1, wl.tokens, wl.latent_dim))
        den = wl.denoiser(wl.make_policy("teacache_video", delta=0.1,
                                         num_steps=num_steps),
                          class_label=req.class_label)
        ref, _ = sample(den, xT, ts, sched, step_fn=ddim_step,
                        denoiser_state=den.init_state(1))
        if not np.allclose(res.x0, np.asarray(ref[0]), atol=5e-3, rtol=1e-3):
            failures.append(f"video request {req.request_id}: served output "
                            f"diverged from its cached reference")
            break
    # ...and quality vs the uncached baseline is reported as PSNR
    qs = [float(psnr(a.x0, b.x0))
          for a, b in zip(results["temporal"], results["none"])
          if a.record.modality == "video"]
    q_video = sum(qs) / max(len(qs), 1)
    print(f"video temporal-vs-uncached PSNR: {q_video:.2f} dB")
    if not smoke and q_video < 10.0:
        failures.append(f"video cached output collapsed: {q_video:.2f} dB")

    # serving-level throughput claim on the host-plannable pool
    ratio = (out["static"]["throughput_rps"] / out["none"]["throughput_rps"])
    ratio_t = (out["temporal"]["throughput_rps"] /
               out["none"]["throughput_rps"])
    print(f"static-vs-none mixed-pool throughput: {ratio:.2f}x "
          f"(temporal pool: {ratio_t:.2f}x — pays per-tick want-compute "
          f"round trips that small models don't amortize)")
    if not smoke and ratio <= 1.0:
        failures.append(f"mixed-pool interval caching did not beat none: "
                        f"{ratio:.2f}x")
    return {"throughput_ratio_static": ratio,
            "throughput_ratio_temporal": ratio_t,
            "video_rows": {"temporal": v_cached, "none": v_none},
            "video_psnr_db": q_video,
            "summaries": out}, failures


def run(smoke: bool = False, json_out: bool = False):
    workloads = _workloads(smoke)
    if smoke:
        traj_rows, fails = run_trajectories(workloads, num_steps=8,
                                            smoke=True)
        mixed, mfails = run_mixed_serving(workloads, num_steps=8,
                                          num_requests=6, slots=2,
                                          smoke=True)
    else:
        traj_rows, fails = run_trajectories(workloads, num_steps=24,
                                            smoke=False)
        mixed, mfails = run_mixed_serving(workloads, num_steps=16,
                                          num_requests=12, slots=4,
                                          smoke=False)
    payload = {"trajectories": traj_rows, "mixed": mixed,
               "smoke": smoke, "failures": fails + mfails}
    save_result("modalities", payload)
    if json_out:
        save_result("BENCH_modalities", payload)
    if fails or mfails:
        raise AssertionError("; ".join(fails + mfails))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + few ticks (CI per-PR run)")
    ap.add_argument("--json", action="store_true",
                    help="also write results/BENCH_modalities.json (the "
                         "stable-name copy CI uploads as an artifact)")
    args = ap.parse_args()
    run(smoke=args.smoke, json_out=args.json)
