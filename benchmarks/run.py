"""Benchmark aggregator: `PYTHONPATH=src python -m benchmarks.run`.

Runs one benchmark per survey claim (DESIGN §7) on CPU-feasible model
scales; the roofline table is assembled from the dry-run artifacts if they
exist (run `python -m repro.launch.dryrun --all` to regenerate).
"""
from __future__ import annotations

import sys
import time
import traceback


def main():
    from benchmarks import (bench_decode_cache, bench_error, bench_memory,
                            bench_modalities, bench_quality, bench_roofline,
                            bench_serving, bench_speca, bench_speedup)
    benches = [
        ("speedup (T/m claim, §III-B)", bench_speedup.run),
        ("error-vs-interval (TaylorSeer/HiCache/FoCa, §III-D3)", bench_error.run),
        ("cache memory (FreqCa CRF, Eq. 52)", bench_memory.run),
        ("speculative caching (SpeCa Eq. 57)", bench_speca.run),
        ("adaptive quality + exact cross-KV (§III-D1, §I-C)", bench_quality.run),
        ("beyond-paper: decode-axis caching", bench_decode_cache.run),
        ("serving throughput vs policy (continuous batching)", bench_serving.run),
        ("multi-modal caching (image/video/audio + mixed pool)",
         bench_modalities.run),
        ("roofline table (from dry-run artifacts)", bench_roofline.run),
    ]
    import gc
    import jax
    failures = []
    for name, fn in benches:
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        try:
            fn()
            print(f"----- done in {time.perf_counter()-t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()
        # compiled eager/jit programs accumulate across benches and can
        # exhaust host RAM (LLVM "Cannot allocate memory")
        jax.clear_caches()
        gc.collect()
    print("\n==== SUMMARY ====")
    print("failed:", failures if failures else "none — all benchmarks ran")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
