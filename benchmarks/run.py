"""Benchmark aggregator: `PYTHONPATH=src python -m benchmarks.run`.

Runs one benchmark per survey claim (DESIGN §7) on CPU-feasible model
scales; the roofline table is assembled from the dry-run artifacts if they
exist (run `python -m repro.launch.dryrun --all` to regenerate).

`--json` additionally writes one machine-readable `BENCH_<name>.json` per
benchmark into benchmarks/results/ — pass/fail, wall seconds, and the
error text on failure — so CI and tracking dashboards can diff benchmark
health across commits without parsing stdout.  The per-claim payloads the
benchmarks save themselves (benchmarks/results/<claim>.json) are
unaffected.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(write_json: bool = False):
    from benchmarks import (bench_decode_cache, bench_error, bench_memory,
                            bench_modalities, bench_quality, bench_roofline,
                            bench_serving, bench_speca, bench_speedup)
    from benchmarks.common import save_result
    benches = [
        ("speedup", "speedup (T/m claim, §III-B)", bench_speedup.run),
        ("error", "error-vs-interval (TaylorSeer/HiCache/FoCa, §III-D3)",
         bench_error.run),
        ("memory", "cache memory (FreqCa CRF, Eq. 52)", bench_memory.run),
        ("speca", "speculative caching (SpeCa Eq. 57)", bench_speca.run),
        ("quality", "adaptive quality + exact cross-KV (§III-D1, §I-C)",
         bench_quality.run),
        ("decode_cache", "beyond-paper: decode-axis caching",
         bench_decode_cache.run),
        ("serving", "serving throughput vs policy (continuous batching)",
         bench_serving.run),
        ("modalities", "multi-modal caching (image/video/audio + mixed pool)",
         bench_modalities.run),
        ("roofline", "roofline table (from dry-run artifacts)",
         bench_roofline.run),
    ]
    import gc
    import jax
    failures = []
    for slug, name, fn in benches:
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        err = None
        try:
            fn()
            print(f"----- done in {time.perf_counter()-t0:.1f}s")
        except Exception:
            failures.append(name)
            err = traceback.format_exc()
            traceback.print_exc()
        if write_json:
            save_result(f"BENCH_{slug}", {
                "bench": slug, "title": name, "ok": err is None,
                "seconds": round(time.perf_counter() - t0, 3),
                "error": err})
        # compiled eager/jit programs accumulate across benches and can
        # exhaust host RAM (LLVM "Cannot allocate memory")
        jax.clear_caches()
        gc.collect()
    print("\n==== SUMMARY ====")
    print("failed:", failures if failures else "none — all benchmarks ran")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<name>.json status files to "
                         "benchmarks/results/")
    args = ap.parse_args()
    main(write_json=args.json)
