"""Claim: SpeCa's speedup S ~= 1/((1-alpha)+gamma) where alpha is the
prediction acceptance rate and gamma the (small) verification cost ratio
(survey Eq. 57).

We run SpeCa at several tolerances with an oracle verifier, read the
acceptance/rejection counters from the policy state, and compare the
realized compute fraction against the formula.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import make_policy
from repro.core.metrics import psnr, rel_l2
from repro.diffusion import ddim_step, sample
from repro.models import dit

from .common import save_result, small_dit, trajectory_reference

NUM_STEPS = 40
INTERVAL = 4


def run():
    cfg, params = small_dit()
    sched, ts, xT, x0_ref, _ = trajectory_reference(params, cfg, NUM_STEPS)
    B = xT.shape[0]
    y = jnp.zeros((B,), jnp.int32)

    rows = []
    for tau in (0.02, 0.05, 0.1, 0.3):
        pol = make_policy("speca", interval=INTERVAL, tau=tau)
        state = pol.init_state(xT.shape)

        def denoise(state, i, x, t, _pol=pol):
            def compute(lat):
                return dit.forward(params, lat, t, y, cfg)

            def verify(lat, y_hat):
                return rel_l2(y_hat, compute(lat))

            return _pol.apply(state, i, x, compute, verify_fn=verify)

        x0, state = sample(denoise, xT, ts, sched, step_fn=ddim_step,
                           denoiser_state=state)
        x0 = np.asarray(x0)
        acc, rej = int(state["accepts"]), int(state["rejects"])
        scheduled = sum(1 for s in range(NUM_STEPS) if s % INTERVAL == 0)
        frac = (scheduled + rej) / NUM_STEPS
        alpha = acc / max(acc + rej, 1)
        gamma = 0.05                      # probe cost ratio in production
        s_formula = 1.0 / ((1.0 - alpha) + gamma)
        rows.append({
            "tau": tau, "accepts": acc, "rejects": rej,
            "compute_fraction": frac, "alpha": alpha,
            "speedup_formula": s_formula,
            "speedup_fraction_based": 1.0 / frac,
            "psnr_vs_exact": float(psnr(x0, x0_ref)),
        })
        print(f"tau={tau}: acc={acc} rej={rej} frac={frac:.2f} "
              f"alpha={alpha:.2f} S_formula={s_formula:.2f} "
              f"S_realized={1/frac:.2f} psnr={rows[-1]['psnr_vs_exact']:.1f}")

    claims = {
        "alpha_nondecreasing_with_tau": all(
            rows[i]["alpha"] <= rows[i + 1]["alpha"] + 1e-9
            for i in range(len(rows) - 1)),
        "tight_tau_higher_quality":
            rows[0]["psnr_vs_exact"] >= rows[-1]["psnr_vs_exact"] - 1e-6,
    }
    print("claims:", claims)
    save_result("bench_speca", {"rows": rows, "claims": claims})
    return rows, claims


if __name__ == "__main__":
    run()
