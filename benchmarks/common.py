"""Shared benchmark harness utilities."""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.clock import monotonic

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_result(name: str, payload: dict):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def small_dit(seed: int = 0, layers: int = 6, d_model: int = 256,
              tokens: int = 64, in_dim: int = 16):
    """A ~5M-param DiT used by every cache benchmark: big enough that cache
    hits matter, small enough for CPU."""
    from repro.configs import get_config
    from repro.models import init_params
    cfg = get_config("dit-xl").reduced(
        num_layers=layers, d_model=d_model, num_heads=4, num_kv_heads=4,
        d_ff=d_model * 4, dit_patch_tokens=tokens, dit_in_dim=in_dim,
        dit_num_classes=10)
    from repro.models import perturb_zero_init
    params = perturb_zero_init(init_params(jax.random.PRNGKey(seed), cfg), seed)
    return cfg, params


def trajectory_reference(params, cfg, num_steps: int, batch: int = 2,
                         seed: int = 0, cfg_scale: float = 0.0):
    """Exact (uncached) sampling trajectory + per-step model outputs."""
    from repro.diffusion import linear_schedule, sample, ddim_step
    from repro.diffusion.pipeline import cfg_denoise_fn
    sched = linear_schedule(1000)
    ts = sched.spaced(num_steps)
    key = jax.random.PRNGKey(seed)
    xT = jax.random.normal(key, (batch, cfg.dit_patch_tokens, cfg.dit_in_dim))
    outputs = []

    base = cfg_denoise_fn(params, cfg, cfg_scale)

    def recording(state, i, x, t):
        eps, state = base(state, i, x, t)
        outputs.append(np.asarray(eps))
        return eps, state

    x0, _ = sample(recording, xT, ts, sched, step_fn=ddim_step)
    return sched, ts, xT, np.asarray(x0), outputs


def run_policy(policy, params, cfg, sched, ts, xT, granularity="model",
               cfg_scale: float = 0.0):
    """Sample under a cache policy; returns (x0, n_computed_steps)."""
    from repro.diffusion import sample, ddim_step
    from repro.diffusion.pipeline import CachedDenoiser
    den = CachedDenoiser(params, cfg, policy, granularity=granularity,
                         cfg_scale=cfg_scale)
    counter = {"n": 0}
    orig = den._backbone

    def counting(x_lat, t_vec, y, state, step):
        counter["n"] += 1
        return orig(x_lat, t_vec, y, state, step)

    # count *full computes* via policy state where available instead
    x0, state = sample(den, xT, ts, sched, step_fn=ddim_step,
                       denoiser_state=den.init_state(xT.shape[0]))
    n_comp = None
    pol = state.get("policy", {})
    if isinstance(pol, dict) and "n_compute" in pol:
        n_comp = int(pol["n_compute"])
    return np.asarray(x0), n_comp


def timeit(fn, *args, reps: int = 3, warmup: int = 1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = monotonic()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (monotonic() - t0) / reps
