"""Serving throughput/latency vs cache policy at several slot counts.

The survey's speedups are single-trajectory; this benchmark measures what
they buy at the *serving* level: request throughput and end-to-end latency
of the continuous-batching engine under a mixed-budget request queue.  With
phase-aligned admission, an interval-N policy turns (N-1)/N of all engine
ticks into cheap forecast/reuse programs, so cached policies should beat
`none` on request throughput at equal slot count — that claim is checked and
saved in the result payload.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, small_dit

NUM_REQUESTS = 18
BUDGETS = (8, 12, 16)
POLICIES = [
    ("none", {}),
    ("fora", {"interval": 4}),
    ("taylorseer", {"interval": 4, "order": 2}),
    ("teacache", {"delta": 0.1}),
]
SLOT_COUNTS = (2, 6)


def _requests():
    from repro.serving.diffusion import DiffusionRequest
    return [DiffusionRequest(i, num_steps=BUDGETS[i % len(BUDGETS)], seed=i)
            for i in range(NUM_REQUESTS)]


def run():
    from repro.core import make_policy
    from repro.serving.diffusion import DiffusionRequest, DiffusionServingEngine

    cfg, params = small_dit()   # the shared ~5M-param cache-benchmark DiT
    rows = []
    print(f"{'policy':12s} {'slots':>5s} {'req/s':>8s} {'p50 lat':>9s} "
          f"{'cf':>6s} {'full-tick%':>10s}")
    for slots in SLOT_COUNTS:
        for name, kw in POLICIES:
            policy = make_policy(name, num_steps=max(BUDGETS), **kw)
            eng = DiffusionServingEngine(params, cfg, policy, slots=slots,
                                         max_steps=max(BUDGETS))
            # warm the two compiled tick programs so the timed run measures
            # steady-state serving, not XLA compilation
            eng.serve([DiffusionRequest(10_000 + i, num_steps=BUDGETS[0],
                                        seed=i) for i in range(slots)])
            res = eng.serve(_requests())
            s = eng.telemetry.summary()
            assert len(res) == NUM_REQUESTS
            assert all(np.isfinite(r.x0).all() for r in res)
            rows.append({"policy": name, "slots": slots, **s})
            print(f"{name:12s} {slots:5d} {s['throughput_rps']:8.2f} "
                  f"{s['latency_p50_s']:8.3f}s {s['compute_fraction_mean']:6.3f} "
                  f"{100 * s['full_tick_fraction']:9.1f}%")

    # the serving-level claim: caching raises request throughput
    comparisons = {}
    for slots in SLOT_COUNTS:
        base = next(r for r in rows
                    if r["policy"] == "none" and r["slots"] == slots)
        for name, _ in POLICIES[1:]:
            r = next(x for x in rows
                     if x["policy"] == name and x["slots"] == slots)
            comparisons[f"{name}@{slots}"] = \
                r["throughput_rps"] / base["throughput_rps"]
    best = max(comparisons.values())
    print(f"best cached-vs-none throughput gain: {best:.2f}x")
    save_result("serving", {"rows": rows, "throughput_vs_none": comparisons})
    if best <= 1.0:
        raise AssertionError(
            f"no cached policy beat `none` on throughput: {comparisons}")


if __name__ == "__main__":
    run()
