"""Serving throughput/latency vs cache policy at several slot counts.

The survey's speedups are single-trajectory; this benchmark measures what
they buy at the *serving* level: request throughput and end-to-end latency
of the continuous-batching engine under a mixed-budget request queue.  With
phase-aligned admission, an interval-N policy turns (N-1)/N of all engine
ticks into cheap forecast/reuse programs, so cached policies should beat
`none` on request throughput at equal slot count — that claim is checked and
saved in the result payload.

CFG mode (always run, after the unguided sweep): classifier-free guidance
doubles backbone cost; FasterCacheCFG per-slot uncond-branch reuse
(survey §III-C) drops the uncond rows from the backbone batch on reuse
ticks, so guided throughput lands between 1x and 2x of naive two-branch
serving.  The benchmark serves the same guided queue both ways and checks
that the cached engine dispatches measurably fewer uncond backbone rows.

Row-compaction mode (always run, last): a mixed TeaCache + CFG pool is the
worst case for whole-pool ticks — a signal policy firing on ONE slot used to
drag every slot through the backbone, and one uncond refresh doubled the
batch.  The row-compacted engine gathers only the rows whose per-slot
policies want a compute; the benchmark serves the same mixed queue through
the compacted and the dense (PR-3) engine and checks equal output with
strictly fewer backbone rows computed, reporting rows alongside req/s.

Online-tuner mode (always run, last): the control plane's claim.  A
SmoothCache schedule (calibrate once per modality with the safety margin
an offline config needs, serve statically) and an OnlineTuner (quality-
sweep once, tune to the bare floor, then re-price candidates — including
the same schedule family — against the live telemetry window and roll
policies over at refill boundaries) serve the same queue; per-request
quality is scored as a PSNR proxy against a `none`-policy reference
serving the same seeds (request noise is request-keyed, so trajectories
line up across engines).  The tuner must complete the queue at the SLA's
quality floor with req/s matching or beating the static schedule; the gap
measures what live re-pricing saves over offline conservatism.

`--smoke` (used by CI) shrinks the model / queue / tick counts so the whole
benchmark — including the CFG, compaction and online-tuner modes — runs in
seconds; timing-dependent assertions are skipped in smoke mode, structural
ones (rows saved, request completion, output equality, quality floor) are
kept.  `--mode online-tuner` runs just the control-plane comparison (the CI
smoke job uses `--smoke --mode online-tuner`).
"""
from __future__ import annotations

import argparse
from dataclasses import replace

import numpy as np

from benchmarks.common import save_result, small_dit

NUM_REQUESTS = 18
BUDGETS = (8, 12, 16)
POLICIES = [
    ("none", {}),
    ("fora", {"interval": 4}),
    ("taylorseer", {"interval": 4, "order": 2}),
    ("teacache", {"delta": 0.1}),
]
SLOT_COUNTS = (2, 6)

CFG_SCALE = 3.0
CFG_INTERVAL = 4

# online-tuner candidate menu: `none` anchors the quality ceiling, the
# teacache deltas give the tuner intermediate operating points on the
# random bench DiT (whose drift makes interval policies quality-cliff);
# run_control extends this with blockcache schedules built from the live
# calibration profile (the same family the static baseline deploys)
CONTROL_POLICIES = [
    ("none", {}),
    ("teacache", {"delta": 0.06}),
    ("teacache", {"delta": 0.1}),
    ("fora", {"interval": 2}),
]

# schedule operating points shared by the static baseline and the tuner's
# menu: the comparison is then purely margin-vs-live-repricing, not two
# different policy families
CONTROL_ALPHAS = (0.2, 0.1, 0.05, 0.01)


def _requests(num, budgets):
    from repro.serving.diffusion import DiffusionRequest
    return [DiffusionRequest(i, num_steps=budgets[i % len(budgets)], seed=i)
            for i in range(num)]


def _cfg_requests(num, steps):
    from repro.serving.diffusion import DiffusionRequest
    return [DiffusionRequest(i, num_steps=steps, seed=i,
                             class_label=i % 10, cfg_scale=CFG_SCALE)
            for i in range(num)]


def run_unguided(cfg, params, *, num_requests, budgets, slot_counts, smoke):
    from repro.core import make_policy
    from repro.serving.diffusion import DiffusionRequest, DiffusionServingEngine

    rows = []
    print(f"{'policy':12s} {'slots':>5s} {'req/s':>8s} {'p50 lat':>9s} "
          f"{'cf':>6s} {'backbone%':>10s}")
    for slots in slot_counts:
        for name, kw in POLICIES:
            policy = make_policy(name, num_steps=max(budgets), **kw)
            eng = DiffusionServingEngine(params, cfg, policy, slots=slots,
                                         max_steps=max(budgets))
            # warm the compiled tick programs so the timed run measures
            # steady-state serving, not XLA compilation
            eng.serve([DiffusionRequest(10_000 + i, num_steps=budgets[0],
                                        seed=i) for i in range(slots)])
            res = eng.serve(_requests(num_requests, budgets))
            s = eng.telemetry.summary()
            assert len(res) == num_requests
            assert all(np.isfinite(r.x0).all() for r in res)
            rows.append({"policy": name, "slots": slots, **s})
            print(f"{name:12s} {slots:5d} {s['throughput_rps']:8.2f} "
                  f"{s['latency_p50_s']:8.3f}s {s['compute_fraction_mean']:6.3f} "
                  f"{100 * s['full_tick_fraction']:9.1f}%")

    # the serving-level claim: caching raises request throughput
    comparisons = {}
    for slots in slot_counts:
        base = next(r for r in rows
                    if r["policy"] == "none" and r["slots"] == slots)
        for name, _ in POLICIES[1:]:
            r = next(x for x in rows
                     if x["policy"] == name and x["slots"] == slots)
            comparisons[f"{name}@{slots}"] = \
                r["throughput_rps"] / base["throughput_rps"]
    best = max(comparisons.values())
    print(f"best cached-vs-none throughput gain: {best:.2f}x")
    failures = []
    if best <= 1.0 and not smoke:
        failures.append(
            f"no cached policy beat `none` on throughput: {comparisons}")
    return rows, comparisons, failures


def run_cfg(cfg, params, *, num_requests, steps, slots, smoke):
    """Guided serving: naive two-branch vs per-slot FasterCacheCFG reuse."""
    from repro.core import FasterCacheCFG, make_policy
    from repro.serving.diffusion import DiffusionServingEngine

    print(f"\n-- CFG mode (cfg_scale={CFG_SCALE}, "
          f"FasterCacheCFG interval={CFG_INTERVAL}) --")
    print(f"{'uncond':12s} {'req/s':>8s} {'p50 lat':>9s} {'2S-tick%':>9s} "
          f"{'uncond rows':>12s}")
    out = {}
    reqs = _cfg_requests(num_requests, steps)
    # main policy "none" isolates the uncond-branch saving: naive serves 2S
    # backbone rows every tick, FasterCacheCFG drops to S rows on (N-1)/N of
    # them, so the throughput ratio must land between 1x and 2x.  (Stacking
    # a cond-side interval policy on top multiplies further — see the
    # unguided sweep above — but then the naive baseline also loses its
    # skip ticks and the ratio no longer isolates CFG reuse.)
    for mode, cfg_pol in (("naive", None),
                          ("fastercache", FasterCacheCFG(CFG_INTERVAL, steps))):
        eng = DiffusionServingEngine(params, cfg, make_policy("none"),
                                     slots=slots, max_steps=steps,
                                     cfg_policy=cfg_pol)
        eng.serve([replace(r, request_id=10_000 + r.request_id)
                   for r in _cfg_requests(slots, steps)])
        res = eng.serve(reqs)
        s = eng.telemetry.summary()
        assert len(res) == num_requests
        assert all(np.isfinite(r.x0).all() for r in res)
        out[mode] = s
        print(f"{mode:12s} {s['throughput_rps']:8.2f} "
              f"{s['latency_p50_s']:8.3f}s "
              f"{100 * s['cfg_full_tick_fraction']:8.1f}% "
              f"{s['uncond_rows_computed']:12d}")

    ratio = (out["fastercache"]["throughput_rps"] /
             out["naive"]["throughput_rps"])
    saved = out["fastercache"]["uncond_rows_saved"]
    rows_ratio = (out["naive"]["uncond_rows_computed"] /
                  max(out["fastercache"]["uncond_rows_computed"], 1))
    print(f"fastercache-vs-naive CFG throughput: {ratio:.2f}x "
          f"(uncond rows cut {rows_ratio:.1f}x, {saved} saved; backbone-row "
          f"count bounds the ideal gain at 2x — wall clock can wobble past "
          f"it on a noisy host)")
    failures = []
    # structural claim (holds at any model size): CFG reuse dispatches
    # measurably fewer uncond backbone rows than two-branch serving
    if not (out["fastercache"]["uncond_rows_computed"] <
            out["naive"]["uncond_rows_computed"] and saved > 0):
        failures.append(
            f"CFG reuse did not cut uncond backbone rows: "
            f"{ {m: out[m]['uncond_rows_computed'] for m in out} }")
    # timing claim (skipped in smoke mode — tiny models are noise-bound)
    if not smoke and ratio <= 1.0:
        failures.append(
            f"FasterCacheCFG serving did not beat naive two-branch: {ratio}")
    return {"throughput_ratio": ratio,
            "uncond_rows": {m: out[m]["uncond_rows_computed"] for m in out},
            "uncond_rows_saved": saved,
            "summaries": out}, failures


def run_compaction(cfg, params, *, num_requests, steps, slots, smoke):
    """Row-compacted vs dense whole-pool ticks on a mixed TeaCache + CFG
    pool: equal per-request output, strictly fewer backbone rows, req/s no
    worse (timing claim skipped in smoke mode).  Also reports the measured
    redundancy ratio (FLOPs avoided / dense FLOPs, priced from warmup's
    per-bucket XLA cost analysis) and bounds the observability overhead:
    serving the same queue with a TraceRecorder + MetricsRegistry attached
    must stay within 5% req/s of hooks-off serving."""
    from repro.analysis.ir import RetraceSentinel
    from repro.core import FasterCacheCFG
    from repro.obs import (MetricsRegistry, TraceRecorder, redundancy_ratio)
    from repro.serving.diffusion import (DiffusionRequest,
                                         DiffusionServingEngine)

    print(f"\n-- row compaction (teacache + FasterCacheCFG, mixed "
          f"guided/unguided pool, {slots} slots) --")
    print(f"{'engine':12s} {'req/s':>8s} {'p50 lat':>9s} {'rows':>7s} "
          f"{'pad':>5s} {'saved':>7s}")
    reqs = [DiffusionRequest(i, num_steps=steps, seed=i, class_label=i % 10,
                             cfg_scale=CFG_SCALE if i % 2 == 0 else 0.0)
            for i in range(num_requests)]
    out, results, profiles = {}, {}, {}
    engines, recompiles = {}, {}
    for mode, compact in (("compacted", True), ("dense", False)):
        eng = DiffusionServingEngine(params, cfg, "teacache", slots=slots,
                                     max_steps=steps,
                                     cfg_policy=FasterCacheCFG(CFG_INTERVAL,
                                                               steps),
                                     row_compaction=compact)
        # compile every bucket program up front (state-dependent policies
        # surface new bucket sizes mid-run), then warm the host paths;
        # warmup doubles as the program profiler (compile time + FLOPs)
        profiles[mode] = eng.warmup()
        eng.serve([DiffusionRequest(10_000 + i, num_steps=steps, seed=i,
                                    cfg_scale=CFG_SCALE)
                   for i in range(slots)])
        # retrace sentinel: warmup promises the complete program set, so
        # the measured serve must trigger ZERO jit compiles (a silent
        # retrace pays an XLA compile inside a live tick and invalidates
        # the timing claim on top of the latency SLA)
        with RetraceSentinel() as sentinel:
            res = eng.serve(reqs)
        recompiles[mode] = {"count": sentinel.count,
                            "programs": sorted(set(sentinel.compiled_names))}
        assert len(res) == num_requests
        assert all(np.isfinite(r.x0).all() for r in res)
        s = eng.telemetry.summary()
        out[mode], results[mode], engines[mode] = s, res, eng
        print(f"{mode:12s} {s['throughput_rps']:8.2f} "
              f"{s['latency_p50_s']:8.3f}s {s['backbone_rows_computed']:7d} "
              f"{s['backbone_rows_padding']:5d} "
              f"{s['backbone_rows_saved']:7d}")

    # measured redundancy ratio: the survey's step-redundancy claim in
    # FLOPs, priced from the compacted engine's warmup cost cards
    s_c = out["compacted"]
    redundancy = redundancy_ratio(profiles["compacted"],
                                  s_c["backbone_rows_computed"],
                                  s_c["backbone_rows_padding"],
                                  s_c["backbone_rows_saved"])
    print(f"redundancy ratio: {redundancy['redundancy_ratio']:.3f} "
          f"({redundancy['flops_avoided']:.3g} of "
          f"{redundancy['dense_flops']:.3g} dense FLOPs avoided, "
          f"{redundancy['flops_per_row']:.3g} FLOPs/row)")

    # observability overhead: same queue, hooks on (trace + metrics)
    eng = engines["compacted"]
    recorder = TraceRecorder(policy=eng.policy)
    registry = MetricsRegistry()
    res = eng.serve(reqs, hooks=[recorder], metrics=registry)
    assert len(res) == num_requests
    recorder.finish()
    s_obs = eng.telemetry.summary()
    obs_ratio = (s_obs["throughput_rps"] /
                 max(s_c["throughput_rps"], 1e-9))
    print(f"hooks-on (trace+metrics) vs hooks-off req/s: {obs_ratio:.3f}x "
          f"({len(recorder.events)} trace events, "
          f"{len(recorder.cache_events)} cache events)")

    failures = []
    # steady-state serving must never retrace (a compile mid-session means
    # warmup's program-set promise is broken — checked in smoke mode too,
    # the claim is about program identity, not timing)
    for mode, rec in recompiles.items():
        if rec["count"] != 0:
            failures.append(
                f"{mode} engine retraced during steady-state serving: "
                f"{rec['count']} compile(s) ({', '.join(rec['programs'])})")
    # the recorder must reconcile with telemetry even under refill churn
    rec_rows = int(registry.counter(
        "repro_engine_rows_computed_total").value(modality="image"))
    if rec_rows != s_obs["backbone_rows_computed"]:
        failures.append(f"metrics/telemetry row mismatch: {rec_rows} vs "
                        f"{s_obs['backbone_rows_computed']}")
    # timing claim (skipped in smoke mode — tiny models are noise-bound):
    # observability must cost <= 5% req/s
    if not smoke and obs_ratio < 0.95:
        failures.append(f"observability overhead exceeded 5% req/s: "
                        f"{obs_ratio:.3f}x")
    # equal output: compaction only changes which rows are batched, never
    # the per-slot policy step
    for a, b in zip(results["compacted"], results["dense"]):
        if not np.allclose(a.x0, b.x0, atol=1e-3, rtol=1e-3):
            failures.append(f"request {a.request_id}: compacted x0 diverged "
                            f"from dense (max |dx|="
                            f"{np.abs(a.x0 - b.x0).max():.2e})")
            break
    # strictly fewer backbone rows, even counting the pow-2 padding
    rows_compact = (out["compacted"]["backbone_rows_computed"] +
                    out["compacted"]["backbone_rows_padding"])
    rows_dense = out["dense"]["backbone_rows_computed"]
    print(f"backbone rows (incl padding): {rows_compact} compacted vs "
          f"{rows_dense} dense "
          f"({rows_dense / max(rows_compact, 1):.2f}x fewer)")
    if not rows_compact < rows_dense:
        failures.append(f"row compaction did not reduce backbone rows: "
                        f"{rows_compact} vs {rows_dense}")
    ratio = (out["compacted"]["throughput_rps"] /
             out["dense"]["throughput_rps"])
    print(f"compacted-vs-dense throughput: {ratio:.2f}x")
    if not smoke and ratio < 1.0:
        failures.append(f"row compaction regressed throughput: {ratio:.2f}x")
    return {"throughput_ratio": ratio,
            "backbone_rows": {"compacted": rows_compact,
                              "dense": rows_dense},
            "redundancy": redundancy,
            "program_profiles": {
                mode: [p.as_dict() for _, p in sorted(prof.items(), key=str)]
                for mode, prof in profiles.items()},
            "observability_overhead_ratio": obs_ratio,
            "recompiles": recompiles,
            "summaries": out}, failures


def run_control(cfg, params, *, num_requests, steps, slots, smoke,
                psnr_floor=15.0, psnr_margin=10.0, retune_every=8):
    """Online control plane vs the calibrated static baseline: the tuner
    must hold the SLA's quality floor while matching/beating SmoothCache's
    req/s on the same queue.

    The baseline is calibrated the way an offline config must be — to the
    floor PLUS a safety margin (it cannot re-pick once traffic starts, so
    it absorbs calibration-vs-traffic drift up front).  The tuner tunes to
    the bare floor: its window re-prices every candidate while serving and
    rolls over if its pick turns out mispriced, so it needs no margin.
    Both choose from the same schedule family (CONTROL_ALPHAS) plus the
    dynamic CONTROL_POLICIES, making the measured gap the value of live
    re-pricing itself."""
    from benchmarks.common import run_policy, trajectory_reference
    from repro.obs.clock import monotonic
    from repro.core.metrics import psnr
    from repro.serving.control import (OnlineTuner, SmoothCacheSchedule,
                                       calibration_profile)
    from repro.serving.diffusion import SLA, DiffusionServingEngine

    print(f"\n-- online tuner vs SmoothCache static ({slots} slots, "
          f"{num_requests} reqs x {steps} steps, psnr floor "
          f"{psnr_floor:.0f}dB) --")
    reqs = _requests(num_requests, (steps,))
    warm = _requests(slots, (steps,))

    # reference trajectories: a `none` engine serving the same request ids
    # (request-keyed noise -> identical xT per request across engines)
    ref_eng = DiffusionServingEngine(params, cfg, "none", slots=slots,
                                     max_steps=steps)
    ref = {r.request_id: r.x0 for r in ref_eng.serve(reqs)}

    def quality(results):
        return {r.request_id: float(psnr(ref[r.request_id], r.x0))
                for r in results}

    out = {}
    print(f"{'server':12s} {'req/s':>8s} {'cf':>6s} {'psnr(dB)':>9s} "
          f"{'swaps':>6s}")

    # static baseline: profile once, then take the loosest alpha whose
    # calibrated PSNR clears floor + margin — the conservative pick an
    # offline deployment has to make (it cannot re-tune under traffic)
    profile = calibration_profile(params, cfg, steps)
    sched_n, ts, xT, ref_x0, _ = trajectory_reference(params, cfg, steps,
                                                      batch=1)
    target = psnr_floor + psnr_margin
    sc, sc_cal_psnr = None, float("inf")
    for alpha in CONTROL_ALPHAS:
        cand = SmoothCacheSchedule(profile, alpha)
        x0, _ = run_policy(cand, params, cfg, sched_n, ts, xT)
        q = float(psnr(ref_x0, x0))
        sc, sc_cal_psnr = cand, q
        if q >= target:
            break           # loosest-first: first hit is the cheapest
    print(f"smoothcache calibrated: alpha={sc.alpha} "
          f"cf={sc.compute_fraction:.3f} ({sc_cal_psnr:.1f}dB calibration, "
          f"target {target:.0f}dB = floor + {psnr_margin:.0f}dB margin)")
    sc_eng = DiffusionServingEngine(params, cfg, sc, slots=slots,
                                    max_steps=steps)
    sc_eng.serve([replace(r, request_id=10_000 + r.request_id)
                  for r in warm])
    sc_res = sc_eng.serve(reqs)
    s = sc_eng.telemetry.summary()
    sc_psnr = quality(sc_res)
    out["smoothcache"] = {"throughput_rps": s["throughput_rps"],
                          "compute_fraction": s["compute_fraction_mean"],
                          "psnr_mean": float(np.mean(list(sc_psnr.values()))),
                          "schedule": sc.static_schedule(steps)}
    print(f"{'smoothcache':12s} {s['throughput_rps']:8.2f} "
          f"{s['compute_fraction_mean']:6.3f} "
          f"{out['smoothcache']['psnr_mean']:9.1f} {'-':>6s}")

    # online tuner: sweep once over the dynamic candidates PLUS the same
    # schedule family the baseline deploys, then live re-pricing with
    # rollover.  Tuned to the BARE floor: the window's row pricing and
    # plan-time surcharge let it pick the cheapest candidate that holds it
    # (and roll back if live timings prove the pick wrong).
    menu = CONTROL_POLICIES + [
        ("blockcache", {"profile": profile, "delta": a})
        for a in CONTROL_ALPHAS]
    tuner = OnlineTuner(params, cfg, SLA(min_psnr=psnr_floor), slots=slots,
                        max_steps=steps, candidates=menu,
                        retune_every=retune_every, min_window_ticks=4,
                        initial=("none", {}), warmup=False)
    # compile every candidate's engine up front (what a deployed control
    # plane does with its candidate menu) so the timed run measures
    # steady-state rollovers, not XLA compiles
    tuner.prewarm()
    tuner.submit_all([replace(r, request_id=10_000 + r.request_id)
                      for r in warm])
    tuner.drain()
    t0 = monotonic()
    tuner.submit_all(reqs)
    tun_res = [r for r in tuner.drain() if r.request_id < 10_000]
    elapsed = monotonic() - t0
    tun_psnr = quality(tun_res)
    for rid, db in tun_psnr.items():
        tuner.window.note_psnr(rid, db)
    out["online_tuner"] = {
        "throughput_rps": len(tun_res) / elapsed if elapsed > 0 else 0.0,
        "compute_fraction": tuner.window.compute_fraction(),
        "psnr_mean": float(np.mean(list(tun_psnr.values()))),
        "policy": tuner.current.policy_name, "swaps": len(tuner.swaps),
        "swap_log": [{k: v for k, v in sw.items() if k != "time"}
                     for sw in tuner.swaps],
        "summary": tuner.summary()}
    print(f"{'online':12s} {out['online_tuner']['throughput_rps']:8.2f} "
          f"{out['online_tuner']['compute_fraction']:6.3f} "
          f"{out['online_tuner']['psnr_mean']:9.1f} "
          f"{len(tuner.swaps):6d}  -> {tuner.current.policy_name}")

    ratio = (out["online_tuner"]["throughput_rps"] /
             max(out["smoothcache"]["throughput_rps"], 1e-9))
    print(f"online-vs-static throughput: {ratio:.2f}x "
          f"(tuner landed on '{tuner.current.policy_name}' after "
          f"{len(tuner.swaps)} swap(s))")
    failures = []
    if len(tun_res) != num_requests:
        failures.append(f"online tuner completed {len(tun_res)} of "
                        f"{num_requests} requests")
    # structural quality claim: the tuner holds the SLA floor it tuned to
    if out["online_tuner"]["psnr_mean"] < psnr_floor:
        failures.append(f"online tuner broke the quality floor: "
                        f"{out['online_tuner']['psnr_mean']:.1f}dB "
                        f"< {psnr_floor}dB")
    # timing claim (skipped in smoke mode): matching-or-beating the static
    # schedule, with a small tolerance for host timing noise
    if not smoke and ratio < 0.95:
        failures.append(f"online tuner fell behind the SmoothCache static "
                        f"baseline on req/s: {ratio:.2f}x")
    return {"throughput_ratio": ratio, **out}, failures


def run(smoke: bool = False, mode: str = "all", json_out: bool = False,
        profile_dir: str = None):
    if smoke:
        cfg, params = small_dit(layers=2, d_model=64, tokens=16, in_dim=8)
        sizes = dict(num_requests=4, steps=8, slots=2, smoke=True)
        # teacache@0.06 calibrates to ~25dB/0.75cf on this model: a real
        # intermediate point between `none` and the quality cliff
        control_kw = dict(psnr_floor=15.0, retune_every=8)
    else:
        cfg, params = small_dit()  # the shared ~5M-param cache-benchmark DiT
        sizes = dict(num_requests=12, steps=16, slots=4, smoke=False)
        control_kw = dict(psnr_floor=5.0, retune_every=16)

    from repro.obs import profiler_trace

    payload, fails = {"smoke": smoke, "mode": mode}, []
    with profiler_trace(profile_dir):
        if mode in ("all", "throughput"):
            if smoke:
                rows, comparisons, f = run_unguided(
                    cfg, params, num_requests=6, budgets=(4, 8),
                    slot_counts=(2,), smoke=True)
            else:
                rows, comparisons, f = run_unguided(
                    cfg, params, num_requests=NUM_REQUESTS, budgets=BUDGETS,
                    slot_counts=SLOT_COUNTS, smoke=False)
            payload.update(rows=rows, throughput_vs_none=comparisons)
            fails += f
        if mode in ("all", "cfg"):
            payload["cfg"], f = run_cfg(cfg, params, **sizes)
            fails += f
        if mode in ("all", "compaction"):
            payload["compaction"], f = run_compaction(cfg, params, **sizes)
            fails += f
        if mode in ("all", "online-tuner"):
            payload["control"], f = run_control(cfg, params, **sizes,
                                                **control_kw)
            fails += f
    payload["failures"] = fails
    # save the payload before raising so a failed claim is still diagnosable
    save_result("serving" if mode == "all" else f"serving_{mode}", payload)
    if json_out:
        # the CI-artifact / seed-comparison copy: a stable BENCH_* name the
        # workflow uploads and the repo pins a seed snapshot of
        save_result("BENCH_serving" if mode == "all"
                    else f"BENCH_serving_{mode.replace('-', '_')}", payload)
    if fails:
        raise AssertionError("; ".join(fails))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config + few ticks (CI per-PR run)")
    ap.add_argument("--mode", default="all",
                    choices=("all", "throughput", "cfg", "compaction",
                             "online-tuner"),
                    help="run one benchmark section instead of all of them")
    ap.add_argument("--json", action="store_true",
                    help="also write results/BENCH_serving*.json (the "
                         "stable-name copy CI uploads as an artifact)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler trace of the whole run "
                         "into this directory (TensorBoard/Perfetto)")
    args = ap.parse_args()
    run(smoke=args.smoke, mode=args.mode, json_out=args.json,
        profile_dir=args.profile_dir)
