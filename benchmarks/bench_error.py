"""Claim: naive reuse error grows with interval; forecasting beats reuse at
high ratios (TaylorSeer §III-D3); Hermite contraction stabilizes high
orders (HiCache Eq. 47).

For each policy and reuse interval N we sample a trajectory on the same
seed and report output MSE / PSNR vs the exact (uncached) trajectory.
"""
from __future__ import annotations

import numpy as np

from repro.core import make_policy
from repro.core.metrics import psnr

from .common import save_result, small_dit, trajectory_reference, run_policy

NUM_STEPS = 40
POLICIES = ["fora", "delta_dit", "taylorseer", "newtonseer", "hicache",
            "abcache", "foca", "freqca", "toca"]


def run():
    cfg, params = small_dit()
    sched, ts, xT, x0_ref, _ = trajectory_reference(params, cfg, NUM_STEPS)

    rows = []
    for name in POLICIES:
        for interval in (2, 4, 8):
            pol = make_policy(name, interval=interval)
            x0, _ = run_policy(pol, params, cfg, sched, ts, xT)
            mse = float(np.mean((x0 - x0_ref) ** 2))
            rows.append({"policy": name, "interval": interval, "mse": mse,
                         "psnr": float(psnr(x0, x0_ref))})
            print(f"{name:12s} N={interval}: mse={mse:.3e} "
                  f"psnr={rows[-1]['psnr']:.1f}")

    # claim checks
    by = {(r["policy"], r["interval"]): r["mse"] for r in rows}
    checks = {
        "reuse_error_grows_with_interval":
            by[("fora", 2)] < by[("fora", 4)] < by[("fora", 8)],
        "taylor_beats_reuse_at_N4": by[("taylorseer", 4)] < by[("fora", 4)],
        "taylor_beats_reuse_at_N8": by[("taylorseer", 8)] < by[("fora", 8)],
        "predictive_best_overall": min(
            by[(p, 4)] for p in ("taylorseer", "hicache", "foca", "abcache"))
            < by[("fora", 4)],
    }
    print("claims:", checks)
    save_result("bench_error", {"rows": rows, "claims": checks})
    return rows, checks


if __name__ == "__main__":
    run()
