"""Claim: FreqCa's CRF caching is O(1) in depth vs O(L) for per-block
caches, ~99% memory saving (survey Eq. 52, §V-A); TaylorSeer's per-layer
history costs O(order * L).

We measure actual cache-state bytes held by each policy at BLOCK vs MODEL
granularity on the benchmark DiT.
"""
from __future__ import annotations

import jax

from repro.core import CachedStack, cache_state_bytes, make_policy
from repro.diffusion.pipeline import CachedDenoiser

from .common import save_result, small_dit


def run():
    cfg, params = small_dit()
    B = 2
    rows = []
    for name, gran in [
        ("fora", "block"), ("fora", "model"),
        ("taylorseer", "block"), ("taylorseer", "model"),
        ("hicache", "block"), ("hicache", "model"),
        ("freqca", "model"),      # CRF: one cumulative-residual tensor
        ("teacache", "model"),
    ]:
        pol = make_policy(name)
        den = CachedDenoiser(params, cfg, pol, granularity=gran)
        state = den.init_state(B)
        nbytes = cache_state_bytes(state)
        rows.append({"policy": name, "granularity": gran, "bytes": nbytes})
        print(f"{name:12s} {gran:6s}: {nbytes/1e6:8.2f} MB")

    by = {(r["policy"], r["granularity"]): r["bytes"] for r in rows}
    block = by[("taylorseer", "block")]
    model = by[("freqca", "model")]

    # O(1)-in-depth check: the CRF cache must not grow with L while the
    # per-block cache grows linearly
    cfg12, params12 = small_dit(layers=12)
    den12 = CachedDenoiser(params12, cfg12, make_policy("freqca"),
                           granularity="model")
    crf12 = cache_state_bytes(den12.init_state(B))
    blk12 = cache_state_bytes(
        CachedDenoiser(params12, cfg12, make_policy("taylorseer"),
                       granularity="block").init_state(B))
    claims = {
        "block_cache_scales_with_L": blk12 > 1.8 * block,  # 12L vs 6L
        "crf_vs_per_block_saving_pct": 100.0 * (1 - model / block),
        "crf_is_O1_in_depth": crf12 == model,              # 12L == 6L bytes
    }
    print("claims:", claims)
    save_result("bench_memory", {"rows": rows, "claims": claims,
                                 "num_layers": cfg.num_layers})
    return rows, claims


if __name__ == "__main__":
    run()
